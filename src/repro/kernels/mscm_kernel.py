"""Pallas TPU kernels for Masked Sparse Chunk Multiplication.

Three kernels, all driven by a scalar-prefetched active-block list that the
caller sorts by chunk id (paper §4, final optimization: evaluate blocks in
chunk order so each chunk enters fast memory once). On TPU the sort is not
merely a cache *hint*: Pallas's pipelining skips re-copying an input block
whose ``index_map`` output is unchanged between consecutive grid steps, so a
chunk-sorted grid makes the chunk tile *structurally* VMEM-resident across
all the queries that hit it.

Kernels
-------
``fused``      dense-lookup analogue for small/medium d: the query's dense
               row lives in VMEM, the gather at the chunk's ELL rows happens
               in-kernel, followed by a [1,R]×[R,B] contraction.
``pregather``  huge-d path (e.g. enterprise d = 4M, a dense row would blow
               VMEM): XLA gathers x at chunk rows in HBM, the kernel streams
               the pre-gathered [A, R] rows against chunk tiles.
``grouped``    MXU-tiled batch path: blocks grouped per chunk into query
               tiles of QT rows → one [QT,R]×[R,B] matmul per tile, with the
               beam-search epilogue (σ(logit) ⊗ parent score, paper eq. 5)
               optionally fused into the kernel body so logits never
               round-trip through HBM between matmul and beam step. Grouping
               is device-side (:func:`repro.kernels.ops.group_blocks_device`)
               so the whole traversal compiles as one XLA program; the
               host-side :func:`group_blocks_by_chunk` remains as the
               reference grouping used by tests/benchmark accounting.

Alignment notes (TPU target; interpret mode ignores these):
* R is padded to a multiple of 8 by ``ChunkedLayer.from_csc`` (f32 sublanes).
* B is the lane dimension of the chunk tile; B < 128 underutilizes lanes —
  the grouped kernel's tiles put QT on sublanes to compensate, and the
  pack-G-chunks-per-tile variant is evaluated in EXPERIMENTS §Perf.
* The in-kernel gather (``jnp.take``) lowers to a VMEM dynamic gather; the
  fused kernel therefore requires d+1 ≤ ~1M f32 elements (4 MB) per query
  row. ``ops.choose_kernel`` enforces this bound.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


# ---------------------------------------------------------------------------
# fused: in-kernel gather from a VMEM-resident dense query row
# ---------------------------------------------------------------------------

def _fused_body(bq_ref, bc_ref, x_ref, rows_ref, vals_ref, out_ref):
    del bq_ref, bc_ref  # consumed by the index maps
    r = rows_ref[0, :]                                   # [R] int32
    xg = jnp.take(x_ref[0, :], r, mode="clip")           # [R] VMEM gather
    acc = jax.lax.dot_general(
        xg[None, :], vals_ref[0],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                    # [1, B]
    out_ref[0, :] = acc[0]


def mscm_fused(
    x_dense: jax.Array,   # f32 [n, Dp]  (Dp >= d+1; sentinel slot is zero)
    rows: jax.Array,      # int32 [C, R]
    vals: jax.Array,      # f32 [C, R, B]
    block_q: jax.Array,   # int32 [A]  sorted by block_c for chunk reuse
    block_c: jax.Array,   # int32 [A]
    *,
    interpret: bool = False,
) -> jax.Array:
    a = block_q.shape[0]
    _, dp = x_dense.shape
    c, r, b = vals.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(a,),
        in_specs=[
            pl.BlockSpec((1, dp), lambda i, bq, bc: (bq[i], 0)),
            pl.BlockSpec((1, r), lambda i, bq, bc: (bc[i], 0)),
            pl.BlockSpec((1, r, b), lambda i, bq, bc: (bc[i], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, b), lambda i, bq, bc: (i, 0)),
    )
    return pl.pallas_call(
        _fused_body,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((a, b), jnp.float32),
        interpret=interpret,
    )(block_q, block_c, x_dense, rows, vals)


# ---------------------------------------------------------------------------
# pregather: XLA does the HBM gather, kernel streams [1,R] x [R,B]
# ---------------------------------------------------------------------------

def _pregather_body(bc_ref, xg_ref, vals_ref, out_ref):
    del bc_ref
    acc = jax.lax.dot_general(
        xg_ref[...], vals_ref[0],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    out_ref[...] = acc


def mscm_pregather(
    xg: jax.Array,        # f32 [A, R]  pre-gathered query values
    vals: jax.Array,      # f32 [C, R, B]
    block_c: jax.Array,   # int32 [A] sorted
    *,
    interpret: bool = False,
) -> jax.Array:
    a, r = xg.shape
    c, _, b = vals.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(a,),
        in_specs=[
            pl.BlockSpec((1, r), lambda i, bc: (i, 0)),
            pl.BlockSpec((1, r, b), lambda i, bc: (bc[i], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, b), lambda i, bc: (i, 0)),
    )
    return pl.pallas_call(
        _pregather_body,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((a, b), jnp.float32),
        interpret=interpret,
    )(block_c, xg, vals)


# ---------------------------------------------------------------------------
# grouped: host-grouped chunk-major query tiles -> MXU matmuls
# ---------------------------------------------------------------------------

def group_blocks_by_chunk(
    block_c: np.ndarray, qt: int
) -> Tuple[np.ndarray, np.ndarray]:
    """Host-side grouping: pack active blocks into per-chunk tiles of QT.

    Returns
      tile_chunk [T]      chunk id of each tile
      tile_src   [T, QT]  index into the (unsorted) block list, -1 = padding
    """
    order = np.argsort(block_c, kind="stable")
    sorted_c = block_c[order]
    tiles_c, tiles_s = [], []
    i = 0
    a = len(block_c)
    while i < a:
        c = sorted_c[i]
        j = i
        while j < a and sorted_c[j] == c:
            j += 1
        members = order[i:j]
        for t0 in range(0, len(members), qt):
            grp = members[t0 : t0 + qt]
            src = np.full(qt, -1, dtype=np.int32)
            src[: len(grp)] = grp
            tiles_c.append(c)
            tiles_s.append(src)
        i = j
    if not tiles_c:  # degenerate empty input
        tiles_c, tiles_s = [0], [np.full(qt, -1, np.int32)]
    return np.asarray(tiles_c, np.int32), np.stack(tiles_s)


def _grouped_body(tc_ref, xg_ref, ps_ref, vals_ref, out_ref, *, mode):
    del tc_ref
    acc = jax.lax.dot_general(
        xg_ref[0], vals_ref[0],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                    # [QT, B]
    if mode == "prod":
        acc = jax.nn.sigmoid(acc) * ps_ref[0][:, None]
    elif mode == "logsum":
        acc = jax.nn.log_sigmoid(acc) + ps_ref[0][:, None]
    out_ref[0] = acc


def mscm_grouped(
    xg_tiles: jax.Array,   # f32 [T, QT, R] gathered query rows per tile
    vals: jax.Array,       # f32 [C, R, B]
    tile_chunk: jax.Array,  # int32 [T]
    parent_scores: Optional[jax.Array] = None,  # f32 [T, QT] beam scores
    *,
    mode: str = "none",
    interpret: bool = False,
) -> jax.Array:
    """Chunk-major query-tile matmul with an optionally fused beam epilogue.

    ``mode``:
      ``none``    raw logits (the classic masked-matmul contract);
      ``prod``    σ(logit) · parent_score  (paper eq. 5, probability space);
      ``logsum``  logσ(logit) + parent_score  (log space).

    The epilogue runs on the [QT, B] accumulator while it is still in VMEM —
    the combined beam scores are the only thing written back to HBM.
    """
    t, qt, r = xg_tiles.shape
    c, _, b = vals.shape
    if mode not in ("none", "prod", "logsum"):
        raise ValueError(f"unknown epilogue mode {mode!r}")
    if parent_scores is None:
        if mode != "none":
            raise ValueError(
                f"mode={mode!r} combines with the parent beam scores; pass "
                "parent_scores (zeros would silently flatten every score)"
            )
        parent_scores = jnp.zeros((t, qt), jnp.float32)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(t,),
        in_specs=[
            pl.BlockSpec((1, qt, r), lambda i, tc: (i, 0, 0)),
            pl.BlockSpec((1, qt), lambda i, tc: (i, 0)),
            pl.BlockSpec((1, r, b), lambda i, tc: (tc[i], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, qt, b), lambda i, tc: (i, 0, 0)),
    )
    return pl.pallas_call(
        functools.partial(_grouped_body, mode=mode),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((t, qt, b), jnp.float32),
        interpret=interpret,
    )(tile_chunk, xg_tiles, parent_scores, vals)
