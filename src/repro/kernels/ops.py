"""Jitted wrappers around the MSCM Pallas kernels.

On CPU (this container) the kernels run with ``interpret=True`` — the kernel
body executes in Python for correctness validation; TPU is the compile
target. ``interpret=None`` auto-detects.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.mscm import gather_query_rows
from repro.kernels.mscm_kernel import (
    group_blocks_by_chunk,
    mscm_fused,
    mscm_grouped,
    mscm_pregather,
)

# A dense f32 query row above this many elements does not fit comfortably in
# VMEM alongside the chunk tile; fall back to the pre-gathered kernel.
VMEM_ROW_LIMIT = 1 << 20


def _auto_interpret(interpret: Optional[bool]) -> bool:
    if interpret is None:
        return jax.default_backend() != "tpu"
    return bool(interpret)


def sort_blocks_by_chunk(block_q: jax.Array, block_c: jax.Array):
    """In-jit chunk-major ordering (paper Alg. 3 line 6-8) + inverse perm."""
    order = jnp.argsort(block_c, stable=True)
    return block_q[order], block_c[order], order


def unsort(out_sorted: jax.Array, order: jax.Array) -> jax.Array:
    return jnp.zeros_like(out_sorted).at[order].set(out_sorted)


@functools.partial(
    jax.jit, static_argnames=("variant", "sort", "interpret")
)
def mscm_pallas(
    x_dense: jax.Array,   # f32 [n, Dp]
    rows: jax.Array,      # int32 [C, R]
    vals: jax.Array,      # f32 [C, R, B]
    block_q: jax.Array,   # int32 [A]
    block_c: jax.Array,   # int32 [A]
    *,
    variant: str = "auto",
    sort: bool = True,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Masked chunk multiplication via Pallas. Returns f32 [A, B]."""
    interp = _auto_interpret(interpret)
    if variant == "auto":
        variant = "fused" if x_dense.shape[1] <= VMEM_ROW_LIMIT else "pregather"
    if sort:
        bq, bc, order = sort_blocks_by_chunk(block_q, block_c)
    else:
        bq, bc, order = block_q, block_c, None
    if variant == "fused":
        out = mscm_fused(x_dense, rows, vals, bq, bc, interpret=interp)
    elif variant == "pregather":
        xg = gather_query_rows(x_dense, rows, bq, bc)
        out = mscm_pregather(xg, vals, bc, interpret=interp)
    else:
        raise ValueError(f"unknown variant {variant}")
    return unsort(out, order) if order is not None else out


def mscm_pallas_grouped(
    x_dense: jax.Array,
    rows: jax.Array,
    vals: jax.Array,
    block_q: np.ndarray,   # host-side block list (serving batcher owns it)
    block_c: np.ndarray,
    *,
    qt: int = 8,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Batch-mode MXU-tiled MSCM. Host groups blocks per chunk into QT-row
    tiles; one [QT,R]x[R,B] matmul per tile. Returns f32 [A, B] in the
    original block order."""
    interp = _auto_interpret(interpret)
    tile_chunk, tile_src = group_blocks_by_chunk(np.asarray(block_c), qt)
    src = jnp.asarray(tile_src)                    # [T, QT]
    safe_src = jnp.maximum(src, 0)
    bq = jnp.asarray(block_q)[safe_src]            # [T, QT]
    bc = jnp.asarray(tile_chunk)[:, None]          # [T, 1]
    r = rows[jnp.asarray(tile_chunk)]              # [T, R]
    xg = x_dense[bq[..., None], r[:, None, :]]     # [T, QT, R]
    xg = jnp.where((src >= 0)[..., None], xg, 0.0)
    tiles = mscm_grouped(xg, vals, jnp.asarray(tile_chunk), interpret=interp)
    a = len(block_c)
    flat_src = src.reshape(-1)
    flat_tiles = tiles.reshape(-1, vals.shape[2])
    # Route padding slots (src == -1) to a scratch row one past the end.
    dest = jnp.where(flat_src >= 0, flat_src, a)
    out = jnp.zeros((a + 1, vals.shape[2]), jnp.float32)
    return out.at[dest].set(flat_tiles)[:a]
