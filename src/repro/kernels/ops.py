"""Jitted wrappers around the MSCM Pallas kernels.

On CPU (this container) the kernels run with ``interpret=True`` — the kernel
body executes in Python for correctness validation; TPU is the compile
target. ``interpret=None`` auto-detects from the backend; the
``MSCM_FORCE_INTERPRET`` environment variable (``1``/``0``) overrides the
auto-detection so CI can pin interpret mode explicitly.

The grouped path is fully device-resident: :func:`group_blocks_device`
derives the chunk-major query tiles *inside* the jit (no host round-trip),
so the entire multi-level beam search — scatter, group, matmul tiles,
epilogue, top-k — compiles as one XLA program (paper §4, Alg. 3).
"""

from __future__ import annotations

import functools
import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.mscm import gather_query_rows
from repro.kernels.mscm_kernel import (
    mscm_fused,
    mscm_grouped,
    mscm_pregather,
)

# A dense f32 query row above this many elements does not fit comfortably in
# VMEM alongside the chunk tile; fall back to the pre-gathered kernel.
VMEM_ROW_LIMIT = 1 << 20

# Query-tile height of the grouped kernel: rows per [QT, R] x [R, B] matmul.
DEFAULT_QT = 8


def _auto_interpret(interpret: Optional[bool]) -> bool:
    if interpret is None:
        env = os.environ.get("MSCM_FORCE_INTERPRET", "")
        if env != "":
            return env.lower() not in ("0", "false", "no")
        return jax.default_backend() != "tpu"
    return bool(interpret)


def sort_blocks_by_chunk(block_q: jax.Array, block_c: jax.Array):
    """In-jit chunk-major ordering (paper Alg. 3 line 6-8) + inverse perm."""
    order = jnp.argsort(block_c, stable=True)
    return block_q[order], block_c[order], order


def unsort(out_sorted: jax.Array, order: jax.Array) -> jax.Array:
    """Undo a permutation by *gathering* through its inverse.

    ``argsort(order)`` is the inverse permutation; a gather through it is
    TPU-friendly, unlike the scatter ``zeros.at[order].set(out)`` (scatters
    serialize on TPU and block fusion with the consumer).
    """
    return out_sorted[jnp.argsort(order)]


# ---------------------------------------------------------------------------
# Device-side grouping (paper Alg. 3, in-jit)
# ---------------------------------------------------------------------------

def grouped_tile_bound(a: int, qt: int, num_chunks: int) -> int:
    """Static worst-case tile count for A blocks grouped per chunk into
    QT-row tiles.

    The true count is  Σ_c ceil(m_c / qt)  over the chunks present, which is
    bounded by ``ceil(A/qt) + #distinct_chunks`` (each chunk wastes at most
    one ragged tile) and by ``A`` (each tile holds ≥ 1 block). Shapes must be
    static under jit, so we provision ``min`` of the two; padding tiles are
    masked out by the caller.
    """
    return max(1, min(a, -(-a // qt) + min(num_chunks, a)))


def group_blocks_device(
    block_c: jax.Array, qt: int, num_chunks: int
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """In-jit, scatter-free grouping of active blocks into per-chunk tiles.

    The static tile count is :func:`grouped_tile_bound`; every construction
    step is a sort, searchsorted, or gather (no scatters — see ``unsort``).

    Returns
      tile_chunk [T]      chunk id per tile (padding tiles repeat the last
                          real chunk so Pallas re-uses the resident tile
                          instead of DMA-ing a fresh one)
      tile_src   [T, QT]  index into the *unsorted* block list, -1 = padding
      order      [A]      chunk-major permutation of the block list
      flat_pos   [A]      position of sorted block i in the flattened
                          [T*QT] tile layout (strictly increasing)
    """
    a = block_c.shape[0]
    t = grouped_tile_bound(a, qt, num_chunks)
    order = jnp.argsort(block_c, stable=True)
    sc = block_c[order].astype(jnp.int32)                # [A] sorted chunks
    idx = jnp.arange(a, dtype=jnp.int32)
    run_start = jnp.searchsorted(sc, sc, side="left").astype(jnp.int32)
    rank = idx - run_start                               # position in run
    slot = rank % qt
    tile_id = jnp.cumsum((slot == 0).astype(jnp.int32)) - 1
    flat_pos = tile_id * qt + slot                       # strictly increasing
    # Invert sorted-position -> tile-slot by binary search (gather, not
    # scatter): flat slot f is occupied iff some flat_pos equals f.
    fgrid = jnp.arange(t * qt, dtype=flat_pos.dtype)
    j = jnp.minimum(jnp.searchsorted(flat_pos, fgrid), a - 1)
    hit = flat_pos[j] == fgrid
    tile_src = jnp.where(hit, order[j].astype(jnp.int32), -1).reshape(t, qt)
    # Chunk per tile from its slot-0 occupant; padding tiles (all at the
    # tail, chunks ascending) inherit the last real chunk via cummax.
    hit0 = hit.reshape(t, qt)[:, 0]
    j0 = j.reshape(t, qt)[:, 0]
    tile_chunk = jax.lax.cummax(jnp.where(hit0, sc[j0], 0))
    return tile_chunk, tile_src, order, flat_pos


def mscm_grouped_level(
    x_dense: jax.Array,        # f32 [n, Dp]
    rows: jax.Array,           # int32 [C, R]
    vals: jax.Array,           # f32 [C, R, B]
    block_q: jax.Array,        # int32 [A]
    block_c: jax.Array,        # int32 [A]
    parent_scores: Optional[jax.Array] = None,  # f32 [A] (beam scores)
    *,
    qt: int = DEFAULT_QT,
    mode: str = "none",
    interpret: Optional[bool] = None,
) -> jax.Array:
    """One tree level through the MXU-tiled grouped kernel, fully in-jit.

    Groups the active blocks chunk-major on device, gathers the query rows
    into [T, QT, R] tiles, runs one [QT, R] x [R, B] matmul per tile with the
    beam epilogue fused (``mode`` — see :func:`mscm_grouped`), and returns
    the [A, B] block scores in the original block order via a pure-gather
    unsort. Traceable: safe to call inside an enclosing jit.
    """
    interp = _auto_interpret(interpret)
    c, _, b = vals.shape
    tile_chunk, tile_src, order, flat_pos = group_blocks_device(
        block_c, qt, c
    )
    safe_src = jnp.maximum(tile_src, 0)                  # [T, QT]
    bq = block_q[safe_src]                               # [T, QT]
    r = rows[tile_chunk]                                 # [T, R]
    xg = x_dense[bq[..., None], r[:, None, :]]           # [T, QT, R]
    xg = jnp.where((tile_src >= 0)[..., None], xg, 0.0)
    ps = None
    if parent_scores is not None:
        ps = jnp.where(tile_src >= 0, parent_scores[safe_src], 0.0)
    tiles = mscm_grouped(
        xg, vals, tile_chunk, ps, mode=mode, interpret=interp
    )                                                    # [T, QT, B]
    # Gather-based unsort: sorted block i lives at tile flat slot
    # flat_pos[i]; composing with the inverse permutation restores the
    # original block order without a scatter.
    flat = tiles.reshape(-1, b)
    return flat[flat_pos[jnp.argsort(order)]]            # [A, B]


# ---------------------------------------------------------------------------
# Jitted entry points
# ---------------------------------------------------------------------------

@functools.partial(
    jax.jit, static_argnames=("variant", "sort", "interpret")
)
def mscm_pallas(
    x_dense: jax.Array,   # f32 [n, Dp]
    rows: jax.Array,      # int32 [C, R]
    vals: jax.Array,      # f32 [C, R, B]
    block_q: jax.Array,   # int32 [A]
    block_c: jax.Array,   # int32 [A]
    *,
    variant: str = "auto",
    sort: bool = True,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Masked chunk multiplication via Pallas. Returns f32 [A, B]."""
    interp = _auto_interpret(interpret)
    if variant == "auto":
        variant = "fused" if x_dense.shape[1] <= VMEM_ROW_LIMIT else "pregather"
    if sort:
        bq, bc, order = sort_blocks_by_chunk(block_q, block_c)
    else:
        bq, bc, order = block_q, block_c, None
    if variant == "fused":
        out = mscm_fused(x_dense, rows, vals, bq, bc, interpret=interp)
    elif variant == "pregather":
        xg = gather_query_rows(x_dense, rows, bq, bc)
        out = mscm_pregather(xg, vals, bc, interpret=interp)
    else:
        raise ValueError(f"unknown variant {variant}")
    return unsort(out, order) if order is not None else out


@functools.partial(
    jax.jit, static_argnames=("qt", "mode", "interpret")
)
def mscm_pallas_grouped(
    x_dense: jax.Array,
    rows: jax.Array,
    vals: jax.Array,
    block_q: jax.Array,
    block_c: jax.Array,
    parent_scores: Optional[jax.Array] = None,
    *,
    qt: int = DEFAULT_QT,
    mode: str = "none",
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Batch-mode MXU-tiled MSCM, grouped *on device* — one XLA program.

    Blocks are packed per chunk into QT-row tiles in-jit
    (:func:`group_blocks_device`); one [QT, R] x [R, B] matmul per tile, with
    the beam epilogue fused when ``mode`` is ``prod``/``logsum``. Returns
    f32 [A, B] in the original block order.
    """
    return mscm_grouped_level(
        x_dense, rows, vals, block_q, block_c, parent_scores,
        qt=qt, mode=mode, interpret=interpret,
    )
