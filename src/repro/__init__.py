"""repro — MSCM (WWW'22) XMR-tree serving + multi-pod JAX LM framework."""

__version__ = "0.1.0"
