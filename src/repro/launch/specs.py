"""Model inputs: ShapeDtypeStruct specs (dry-run) + demo batches (smoke tests).

Modality frontends are stubs per the assignment: ``[audio]``/``[vlm]`` archs
receive precomputed frame/patch embeddings in their input dict.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.shapes import ShapeSpec
from repro.models.common import ArchConfig
from repro.models import lm as lm_lib


def _train_like_shapes(cfg: ArchConfig, batch: int, seq: int) -> Dict[str, Tuple]:
    """(shape, dtype) entries for a full-sequence (train/prefill) batch."""
    if cfg.family == "encdec":
        return {
            "src_embeds": ((batch, seq, cfg.d_model), jnp.bfloat16),
            "tokens": ((batch, seq), jnp.int32),
            "targets": ((batch, seq), jnp.int32),
        }
    if cfg.family == "vlm":
        n_img = min(cfg.frontend_tokens, max(seq // 2, 8))
        s_txt = seq - n_img
        return {
            "patch_embeds": ((batch, n_img, cfg.d_model), jnp.bfloat16),
            "tokens": ((batch, s_txt), jnp.int32),
            "targets": ((batch, s_txt), jnp.int32),
        }
    return {
        "tokens": ((batch, seq), jnp.int32),
        "targets": ((batch, seq), jnp.int32),
    }


def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    train/prefill -> the batch dict; decode -> (cache, tokens, pos).
    """
    b, s = shape.global_batch, shape.seq_len
    if shape.kind in ("train", "prefill"):
        return {
            k: jax.ShapeDtypeStruct(shp, dt)
            for k, (shp, dt) in _train_like_shapes(cfg, b, s).items()
        }
    # decode: one new token against a cache of length seq_len
    cache = jax.eval_shape(
        lambda: lm_lib.init_cache(
            cfg, b, s, src_len=s if cfg.family == "encdec" else 0
        )
    )
    return {
        "cache": cache,
        "tokens": jax.ShapeDtypeStruct((b,), jnp.int32),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }


def make_demo_batch(cfg: ArchConfig, rng: np.random.Generator, batch: int,
                    seq: int) -> Dict[str, jax.Array]:
    """Concrete random batch matching input_specs (smoke tests/examples)."""
    out: Dict[str, jax.Array] = {}
    for k, (shp, dt) in _train_like_shapes(cfg, batch, seq).items():
        if dt == jnp.int32:
            out[k] = jnp.asarray(rng.integers(0, cfg.vocab, size=shp), jnp.int32)
        else:
            out[k] = jnp.asarray(rng.standard_normal(shp), jnp.float32).astype(dt)
    return out
