"""Post-SPMD HLO text analysis: collective-traffic accounting.

``cost_analysis()`` has no collective numbers, so we parse the optimized HLO
(``compiled.as_text()``): build a symbol table of instruction result shapes,
then for every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute sum the *operand* sizes (per the assignment's roofline
recipe) — result sizes and per-op counts are recorded too.
"""

from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^=]*\)|\S+)\s+([\w\-]+)"
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    """Bytes of a possibly-tuple HLO type string."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_stats(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Per-collective {count, operand_bytes, result_bytes} + totals."""
    sizes: Dict[str, int] = {}
    pending = []  # (opname, result_bytes, operand_names)
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, type_str, op = m.group(1), m.group(2), m.group(3)
        b = _shape_bytes(type_str)
        sizes[name] = b
        base_op = op.rstrip(".0123456789")
        if base_op.endswith("-start"):
            base_op = base_op[: -len("-start")]
        if base_op.endswith("-done"):
            continue  # -done pairs with -start; count once
        if base_op in COLLECTIVES:
            paren = line.find("(")
            args = line[paren + 1 : line.find(")", paren)] if paren >= 0 else ""
            operands = re.findall(r"%?([\w.\-]+)", args)
            operands = [o for o in operands if o in sizes or not o.isdigit()]
            pending.append((base_op, b, operands))

    out: Dict[str, Dict[str, float]] = defaultdict(
        lambda: {"count": 0, "operand_bytes": 0.0, "result_bytes": 0.0}
    )
    for op, res_b, operands in pending:
        ob = sum(sizes.get(o, 0) for o in operands)
        if ob == 0:
            ob = res_b  # fallback: operands not in symbol table
        rec = out[op]
        rec["count"] += 1
        rec["operand_bytes"] += ob
        rec["result_bytes"] += res_b
    total_operand = sum(r["operand_bytes"] for r in out.values())
    total_result = sum(r["result_bytes"] for r in out.values())
    out["TOTAL"] = {
        "count": sum(r["count"] for r in out.values()),
        "operand_bytes": total_operand,
        "result_bytes": total_result,
    }
    return dict(out)
