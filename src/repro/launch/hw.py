"""Target-hardware constants (TPU v5e) for roofline terms."""

PEAK_FLOPS_BF16 = 197e12   # FLOP/s per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_LINK_BW = 50e9         # bytes/s per link


def roofline_terms(*, flops: float, bytes_hbm: float, bytes_collective: float,
                   chips: int) -> dict:
    """The three per-step roofline times (seconds) + dominant term."""
    t_compute = flops / (chips * PEAK_FLOPS_BF16)
    t_memory = bytes_hbm / (chips * HBM_BW)
    t_collective = bytes_collective / (chips * ICI_LINK_BW)
    terms = {
        "compute_s": t_compute,
        "memory_s": t_memory,
        "collective_s": t_collective,
    }
    dom = max(terms, key=terms.get)
    terms["dominant"] = dom.replace("_s", "")
    terms["bound_s"] = terms[dom]
    return terms
