"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never
touches jax device state. Single pod: (data=16, model=16) = 256 chips
(v5e pod); multi-pod adds a leading pod axis: (pod=2, data=16, model=16).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(n_data: int = 1, n_model: int = 1):
    """Small mesh over however many (host) devices exist — tests/examples."""
    n = n_data * n_model
    avail = len(jax.devices())
    if n > avail:
        raise ValueError(f"need {n} devices, have {avail}")
    return jax.make_mesh((n_data, n_model), ("data", "model"))
