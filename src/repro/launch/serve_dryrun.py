import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Enterprise XMR serving dry-run: the paper's own deployment (§6) on the
production mesh.

Lowers + compiles the sharded beam-search serving step for the 100M-label,
d=4M semantic product-search model (tree [64,32,32,32,48] -> 100.7M leaves)
with ShapeDtypeStruct weights — proving the paper's enterprise model fits
and runs on a v5e pod, and reporting its roofline terms. This model does NOT
fit one host (leaf chunk tiles ≈ 309 GB f32); the 16-way label-sharded
layout is the point.

    PYTHONPATH=src python -m repro.launch.serve_dryrun [--batch 1024]
"""

import argparse
import functools
import json
import time
from typing import List

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import mscm as mscm_lib
from repro.core.beam import NEG_INF, beam_step
from repro.core.distributed import shard_map_compat
from repro.launch import hw
from repro.launch.hlo_stats import collective_stats
from repro.launch.mesh import make_production_mesh

# enterprise tree geometry (paper §6: L = 100M, d = 4M, branching 32-ish)
D_FEAT = 4_000_000
BRANCHING = [64, 32, 32, 32, 48]          # level sizes 64 ... 100,663,296
LEVEL_NNZ = 64                             # pruned ranker nnz per column
ELL_R = 768                                # chunk union rows (64 nnz x B overlap)
QUERY_NNZ = 256


def level_sizes() -> List[int]:
    out, n = [], 1
    for b in BRANCHING:
        n *= b
        out.append(n)
    return out


def serve_step_spec(batch: int, beam: int, topk: int, mesh):
    sizes = level_sizes()
    n_levels = len(sizes)
    # abstract weights: chunked ELL per level (bf16 values for serving)
    layer_specs = []
    layer_shardings = []
    for li, size in enumerate(sizes):
        b = BRANCHING[li]
        c = sizes[li - 1] if li else 1
        r = min(ELL_R, ((LEVEL_NNZ * b + 7) // 8) * 8) if li == 0 else ELL_R
        rows = jax.ShapeDtypeStruct((c, r), jnp.int32)
        vals = jax.ShapeDtypeStruct((c, r, b), jnp.bfloat16)
        is_leaf = li == n_levels - 1
        spec_rows = P("model", None) if is_leaf else P()
        spec_vals = P("model", None, None) if is_leaf else P()
        layer_specs.append((rows, vals))
        layer_shardings.append(
            (NamedSharding(mesh, spec_rows), NamedSharding(mesh, spec_vals))
        )
    xi = jax.ShapeDtypeStruct((batch, QUERY_NNZ), jnp.int32)
    xv = jax.ShapeDtypeStruct((batch, QUERY_NNZ), jnp.float32)
    q_shard = NamedSharding(mesh, P("data", None))

    flat_specs = [a for pair in layer_specs for a in pair]
    flat_shards = [a for pair in layer_shardings for a in pair]

    def serve(xi, xv, *layers):
        pairs = [(layers[2 * i], layers[2 * i + 1]) for i in range(n_levels)]
        upper, (leaf_rows, leaf_vals) = pairs[:-1], pairs[-1]

        @functools.partial(
            shard_map_compat, mesh=mesh,
            in_specs=(P("data", None), P("data", None),
                      tuple(P() for _ in range(2 * (n_levels - 1))),
                      P("model", None), P("model", None, None)),
            out_specs=(P("data", None), P("data", None)),
            check_vma=False,
        )
        def run(xi, xv, upper_flat, leaf_rows, leaf_vals):
            n = xi.shape[0]
            xd = mscm_lib.scatter_dense(xi, xv, D_FEAT)
            parent = jnp.zeros((n, 1), jnp.int32)
            scores = jnp.ones((n, 1), jnp.float32)
            for li in range(n_levels - 1):
                rows_l, vals_l = upper_flat[2 * li], upper_flat[2 * li + 1]
                bc = parent.shape[1]
                bq = jnp.repeat(jnp.arange(n, dtype=jnp.int32), bc)
                logits = mscm_lib.mscm_dense_lookup(
                    xd, rows_l, vals_l.astype(jnp.float32), bq, parent.reshape(-1)
                ).reshape(n, bc, BRANCHING[li])
                nb = min(beam, sizes[li])
                parent, scores = beam_step(parent, scores, logits, sizes[li], nb)
            my = jax.lax.axis_index("model")
            c_local = leaf_vals.shape[0]
            bc = parent.shape[1]
            bq = jnp.repeat(jnp.arange(n, dtype=jnp.int32), bc)
            fp = parent.reshape(-1)
            local_c = jnp.clip(fp - my * c_local, 0, c_local - 1)
            logits = mscm_lib.mscm_dense_lookup(
                xd, leaf_rows, leaf_vals.astype(jnp.float32), bq, local_c
            ).reshape(n, bc, BRANCHING[-1])
            mine = ((fp // c_local) == my).reshape(n, bc, 1)
            child = fp.reshape(n, bc, 1) * BRANCHING[-1] + jnp.arange(BRANCHING[-1])
            comb = jnp.where(mine, jax.nn.sigmoid(logits) * scores[..., None], NEG_INF)
            ls, pos = jax.lax.top_k(comb.reshape(n, -1), topk)
            li_ = jnp.take_along_axis(child.reshape(n, -1), pos, axis=1)
            als = jax.lax.all_gather(ls, "model", axis=1).reshape(n, -1)
            ali = jax.lax.all_gather(li_, "model", axis=1).reshape(n, -1)
            gs, gp = jax.lax.top_k(als, topk)
            return gs, jnp.take_along_axis(ali, gp, axis=1).astype(jnp.int32)

        return run(xi, xv, tuple(layers[: 2 * (n_levels - 1)]), leaf_rows, leaf_vals)

    return serve, (xi, xv, *flat_specs), (q_shard, q_shard, *flat_shards)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=1024)
    ap.add_argument("--beam", type=int, default=10)
    ap.add_argument("--topk", type=int, default=10)
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    chips = mesh.devices.size
    fn, specs, shardings = serve_step_spec(args.batch, args.beam, args.topk, mesh)
    t0 = time.time()
    with jax.sharding.set_mesh(mesh):
        compiled = jax.jit(fn, in_shardings=shardings).lower(*specs).compile()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        coll = collective_stats(compiled.as_text())
    flops = float(cost.get("flops", 0)) * chips
    byts = float(cost.get("bytes accessed", 0)) * chips
    cb = coll.get("TOTAL", {}).get("operand_bytes", 0.0) * chips
    terms = hw.roofline_terms(flops=flops, bytes_hbm=byts, bytes_collective=cb,
                              chips=chips)
    sizes = level_sizes()
    rec = {
        "model": f"enterprise L={sizes[-1]:,} d={D_FEAT:,} tree={BRANCHING}",
        "batch": args.batch, "beam": args.beam, "chips": chips,
        "compile_s": round(time.time() - t0, 1),
        "memory": {
            "argument_gb_per_device": mem.argument_size_in_bytes / 1e9,
            "temp_gb_per_device": mem.temp_size_in_bytes / 1e9,
        },
        "roofline": terms,
        "per_query_bound_us": 1e6 * terms["bound_s"] / args.batch,
        "collectives": {k: v for k, v in coll.items()},
    }
    print(json.dumps(rec, indent=1))
    out = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun",
                       f"enterprise__serve__{'multi' if args.multi_pod else 'single'}.json")
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump(rec, f, indent=1)


if __name__ == "__main__":
    main()
