"""Training launcher: step builder (shared with the dry-run) + CPU-runnable
loop with checkpoint/auto-resume, watchdog, straggler stats, and optional
failure injection (exercises the fault-tolerance path end to end).

Usage (CPU, reduced config):
    PYTHONPATH=src python -m repro.launch.train --arch yi-6b --reduced \
        --steps 50 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import functools
import logging
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint.ckpt import Checkpointer
from repro.configs import get_config, reduced_config
from repro.data.lm_data import PrefetchingLoader
from repro.distributed.fault import StepWatchdog, TransientError, run_with_retries
from repro.models import lm as lm_lib
from repro.models.common import ArchConfig
from repro.optim.optimizers import (
    Optimizer,
    ef_compress,
    ef_init,
    get_optimizer,
    warmup_cosine,
)

log = logging.getLogger("repro.train")


def make_train_step(cfg: ArchConfig, optimizer: Optimizer, *,
                    peak_lr: float = 3e-4, warmup: int = 100,
                    total_steps: int = 10_000, compress_grads: bool = False):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    ``compress_grads``: error-feedback bf16 gradient compression — the
    payload that crosses the slow pod/DCN link shrinks 2×; the residual
    lives in opt_state['ef'].
    """

    def train_step(params, opt_state, batch):
        step = opt_state["inner"]["step"]
        lr = warmup_cosine(step, peak=peak_lr, warmup=warmup, total=total_steps)
        (loss, metrics), grads = jax.value_and_grad(
            functools.partial(lm_lib.loss_fn, cfg), has_aux=True
        )(params, batch)
        if compress_grads:
            grads, res = ef_compress(grads, opt_state["ef"])
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        new_params, new_inner = optimizer.update(grads, opt_state["inner"], params, lr)
        new_opt = {"inner": new_inner}
        if compress_grads:
            new_opt["ef"] = res
        metrics = dict(metrics, loss=loss, lr=lr)
        return new_params, new_opt, metrics

    return train_step


def init_opt_state(optimizer: Optimizer, params, *, compress_grads: bool = False):
    state = {"inner": optimizer.init(params)}
    if compress_grads:
        state["ef"] = ef_init(params)
    return state


def train_loop(
    cfg: ArchConfig,
    *,
    steps: int,
    batch: int,
    seq: int,
    ckpt_dir: Optional[str] = None,
    save_every: int = 20,
    seed: int = 0,
    log_every: int = 10,
    inject_failure_at: Optional[int] = None,
    compress_grads: bool = False,
) -> Dict[str, Any]:
    optimizer = get_optimizer(cfg.optimizer)
    step_fn = jax.jit(
        make_train_step(cfg, optimizer, total_steps=max(steps, 10),
                        warmup=max(2, steps // 10), compress_grads=compress_grads),
        donate_argnums=(0, 1),
    )

    params = lm_lib.init_params(cfg, jax.random.PRNGKey(seed))
    opt_state = init_opt_state(optimizer, params, compress_grads=compress_grads)
    start_step = 0

    ckpt = Checkpointer(ckpt_dir) if ckpt_dir else None
    if ckpt and ckpt.latest_step() is not None:
        start_step, restored = ckpt.restore({"params": params, "opt": opt_state})
        params, opt_state = restored["params"], restored["opt"]
        log.info("resumed from step %d", start_step)

    loader = PrefetchingLoader(cfg, seed=seed, batch=batch, seq=seq,
                               start_step=start_step)
    watchdog = StepWatchdog()
    losses = []
    injected = {"done": inject_failure_at is None}

    try:
        for _ in range(start_step, steps):
            step_no, np_batch = next(loader)
            batch_dev = {k: jnp.asarray(v) for k, v in np_batch.items()}

            def one_step():
                nonlocal params, opt_state
                if not injected["done"] and step_no == inject_failure_at:
                    injected["done"] = True
                    raise TransientError(f"injected failure at step {step_no}")
                watchdog.start()
                params, opt_state, metrics = step_fn(params, opt_state, batch_dev)
                jax.block_until_ready(metrics["loss"])
                watchdog.stop()
                losses.append(float(metrics["loss"]))
                if step_no % log_every == 0:
                    log.info("step %d loss %.4f lr %.2e", step_no,
                             float(metrics["loss"]), float(metrics["lr"]))

            def on_retry(attempt, err):
                nonlocal params, opt_state, start_step
                if ckpt and ckpt.latest_step() is not None:
                    _, restored = ckpt.restore({"params": params, "opt": opt_state})
                    params, opt_state = restored["params"], restored["opt"]
                    log.info("restored from checkpoint after %s", err)

            run_with_retries(one_step, on_retry=on_retry)

            if ckpt and (step_no + 1) % save_every == 0:
                ckpt.save(step_no + 1, {"params": params, "opt": opt_state})
    finally:
        loader.close()
        if ckpt:
            ckpt.wait()

    return {
        "losses": losses,
        "watchdog": watchdog.summary(),
        "final_params": params,
        "steps_run": len(losses),
    }


def main() -> None:
    logging.basicConfig(level=logging.INFO, format="%(levelname)s %(message)s")
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--save-every", type=int, default=20)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--inject-failure-at", type=int, default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    out = train_loop(
        cfg, steps=args.steps, batch=args.batch, seq=args.seq,
        ckpt_dir=args.ckpt_dir, save_every=args.save_every,
        inject_failure_at=args.inject_failure_at,
        compress_grads=args.compress_grads,
    )
    print(f"ran {out['steps_run']} steps; "
          f"loss {out['losses'][0]:.4f} -> {out['losses'][-1]:.4f}; "
          f"watchdog {out['watchdog']}")


if __name__ == "__main__":
    main()
