import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

This is how the distribution config is proven coherent without hardware:
512 placeholder host devices build the production meshes, every step
function is lowered with its real shardings, ``.compile()`` must succeed,
and the compiled artifact yields the roofline inputs (FLOPs, bytes,
collective traffic, per-device memory).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh single|multi|both]
Results land in experiments/dryrun/<arch>__<shape>__<mesh>.json (one file
per cell; re-runs skip existing files unless --force).
"""

import argparse
import functools
import json
import time
import traceback
from typing import Any, Dict

import jax

from repro.configs import ARCH_IDS, SHAPES, get_config, runnable
from repro.configs.shapes import ShapeSpec
from repro.distributed.sharding import batch_specs, cache_specs, shard_params
from repro.launch import hw
from repro.launch.hlo_stats import collective_stats
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import input_specs
from repro.launch.train import init_opt_state, make_train_step
from repro.models import lm as lm_lib
from repro.models.common import ArchConfig
from repro.optim.optimizers import get_optimizer

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def _model_flops(cfg: ArchConfig, shape: ShapeSpec) -> float:
    """MODEL_FLOPS = 6·N·D (train) / 2·N·D (fwd); N = active params (MoE)."""
    n = cfg.n_active_params()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def _step_and_specs(cfg: ArchConfig, shape: ShapeSpec, mesh):
    """Build (fn, abstract args, in_shardings) for this cell."""
    specs = input_specs(cfg, shape)
    if shape.kind == "train":
        optimizer = get_optimizer(cfg.optimizer)
        params_s = lm_lib.param_shapes(cfg)
        opt_s = jax.eval_shape(
            functools.partial(init_opt_state, optimizer), params_s
        )
        fn = make_train_step(cfg, optimizer)
        args = (params_s, opt_s, specs)
        shardings = (
            shard_params(params_s, mesh),
            shard_params(opt_s, mesh),
            batch_specs(cfg, specs, mesh),
        )
        donate = (0, 1)
    elif shape.kind == "prefill":
        params_s = lm_lib.param_shapes(cfg)

        def fn(params, batch):
            return lm_lib.prefill(cfg, params, batch, max_len=shape.seq_len)

        args = (params_s, specs)
        shardings = (shard_params(params_s, mesh), batch_specs(cfg, specs, mesh))
        donate = ()
    else:  # decode
        params_s = lm_lib.param_shapes(cfg)

        def fn(params, cache, tokens, pos):
            return lm_lib.decode_step(cfg, params, cache, tokens, pos)

        args = (params_s, specs["cache"], specs["tokens"], specs["pos"])
        shardings = (
            shard_params(params_s, mesh),
            cache_specs(cfg, specs["cache"], mesh),
            batch_specs(cfg, {"t": specs["tokens"]}, mesh)["t"],
            None,
        )
        donate = (1,)
    return fn, args, shardings, donate


def _memory_analysis_dict(compiled) -> Dict[str, Any]:
    try:
        ma = compiled.memory_analysis()
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}
    if ma is None:
        return {}
    out = {}
    for field in (
        "argument_size_in_bytes", "output_size_in_bytes",
        "temp_size_in_bytes", "generated_code_size_in_bytes",
        "alias_size_in_bytes",
    ):
        if hasattr(ma, field):
            out[field] = int(getattr(ma, field))
    if not out:
        out["repr"] = str(ma)
    return out


def _compile_once(cfg: ArchConfig, shape: ShapeSpec, mesh, *, want_memory=True):
    """Lower+compile one configuration; return raw metrics."""
    fn, args, shardings, donate = _step_and_specs(cfg, shape, mesh)
    t0 = time.time()
    with jax.sharding.set_mesh(mesh):
        lowered = jax.jit(
            fn, in_shardings=shardings, donate_argnums=donate
        ).lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
        mem = _memory_analysis_dict(compiled) if want_memory else {}
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        cost = {k: float(v) for k, v in cost.items()
                if isinstance(v, (int, float))}
        hlo = compiled.as_text()
        coll = collective_stats(hlo)
    return {
        "flops": cost.get("flops", 0.0),
        "bytes": cost.get("bytes accessed", 0.0),
        "coll_bytes": coll.get("TOTAL", {}).get("operand_bytes", 0.0),
        "collectives": coll,
        "memory": mem,
        "lower_s": t_lower,
        "compile_s": t_compile,
        "hlo_size": len(hlo),
    }


def _probe_cfg(cfg: ArchConfig, n_layers: int) -> ArchConfig:
    import dataclasses

    kw: Dict[str, Any] = {"n_layers": n_layers, "unroll_layers": True}
    if cfg.family == "encdec":
        kw["n_enc_layers"] = n_layers
    return dataclasses.replace(cfg, **kw)


def _apply_overrides(cfg: ArchConfig, overrides: Dict[str, Any]) -> ArchConfig:
    import dataclasses

    if not overrides:
        return cfg
    coerced = {}
    for k, v in overrides.items():
        cur = getattr(cfg, k)
        if isinstance(cur, bool):
            coerced[k] = v in ("1", "true", "True", True)
        elif isinstance(cur, int):
            coerced[k] = int(v)
        elif isinstance(cur, float):
            coerced[k] = float(v)
        else:
            coerced[k] = v
    return dataclasses.replace(cfg, **coerced)


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             overrides: Dict[str, Any] | None = None) -> Dict[str, Any]:
    cfg = _apply_overrides(get_config(arch), overrides or {})
    shape = SHAPES[shape_name]
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "kind": shape.kind, "family": cfg.family,
    }
    if not runnable(cfg.family, shape):
        rec["status"] = "skipped(full-attention)"
        rec["reason"] = (
            "long_500k needs a sub-quadratic path; this arch is pure full "
            "attention (DESIGN.md §Arch-applicability)"
        )
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = mesh.devices.size

    # 1) the REQUIRED artifact: full config must lower + compile.
    full = _compile_once(cfg, shape, mesh, want_memory=True)
    print(f"[{arch} {shape_name} {mesh_kind}] memory_analysis:", full["memory"])
    print(f"[{arch} {shape_name} {mesh_kind}] raw cost_analysis: "
          f"flops={full['flops']:.3e} bytes={full['bytes']:.3e}")

    # 2) layer-count correction: XLA's HloCostAnalysis counts a while-loop
    #    (lax.scan) body ONCE. Probe at L=1 and L=2; every per-layer metric is
    #    linear in L, so corrected = p1 + (L-1)·(p2 - p1). Verified against
    #    the unrolled small model in tests/test_dryrun_small.py.
    p1 = _compile_once(_probe_cfg(cfg, 1), shape, mesh, want_memory=False)
    p2 = _compile_once(_probe_cfg(cfg, 2), shape, mesh, want_memory=False)
    L = cfg.n_layers

    def corrected(key: str) -> float:
        body = max(p2[key] - p1[key], 0.0)
        return p1[key] + (L - 1) * body

    # cost_analysis/memory_analysis describe the per-device SPMD program;
    # totals for the roofline formula are ×chips.
    flops_dev = max(corrected("flops"), full["flops"])
    bytes_dev = max(corrected("bytes"), full["bytes"])
    coll_dev = max(corrected("coll_bytes"), full["coll_bytes"])
    flops = flops_dev * chips
    bytes_hbm = bytes_dev * chips
    coll_bytes = coll_dev * chips
    model_flops = _model_flops(cfg, shape)
    rec.update(
        status="ok",
        chips=chips,
        lower_s=round(full["lower_s"], 2),
        compile_s=round(full["compile_s"], 2),
        hlo_flops=flops,
        hlo_flops_per_device=flops_dev,
        hlo_bytes=bytes_hbm,
        hlo_bytes_per_device=bytes_dev,
        collective_bytes=coll_bytes,
        collective_bytes_per_device=coll_dev,
        raw_scan_once={k: full[k] for k in ("flops", "bytes", "coll_bytes")},
        probe_l1={k: p1[k] for k in ("flops", "bytes", "coll_bytes")},
        probe_l2={k: p2[k] for k in ("flops", "bytes", "coll_bytes")},
        collectives=full["collectives"],
        memory=full["memory"],
        model_flops=model_flops,
        model_vs_hlo_flops=(model_flops / flops if flops else None),
        roofline=hw.roofline_terms(
            flops=flops, bytes_hbm=bytes_hbm, bytes_collective=coll_bytes,
            chips=chips,
        ),
        hlo_size_chars=full["hlo_size"],
    )
    return rec


def cell_path(arch: str, shape: str, mesh_kind: str, tag: str = "") -> str:
    os.makedirs(OUT_DIR, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    return os.path.join(OUT_DIR, f"{arch}__{shape}__{mesh_kind}{suffix}.json")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--override", nargs="*", default=[],
                    help="ArchConfig overrides k=v (hillclimb lowering)")
    ap.add_argument("--tag", default="",
                    help="suffix for the output json (hillclimb iterations)")
    args = ap.parse_args()

    overrides = dict(kv.split("=", 1) for kv in args.override)
    archs = ARCH_IDS if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    failures = []
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                path = cell_path(arch, shape, mesh_kind, args.tag)
                if os.path.exists(path) and not args.force:
                    print(f"skip existing {path}")
                    continue
                print(f"=== {arch} × {shape} × {mesh_kind} ===", flush=True)
                try:
                    rec = run_cell(arch, shape, mesh_kind, overrides)
                    if overrides:
                        rec["overrides"] = overrides
                except Exception as e:
                    rec = {
                        "arch": arch, "shape": shape, "mesh": mesh_kind,
                        "status": "FAILED", "error": str(e),
                        "traceback": traceback.format_exc()[-4000:],
                    }
                    failures.append((arch, shape, mesh_kind, str(e)))
                    print(f"FAILED: {e}", flush=True)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                if rec.get("status") == "ok":
                    r = rec["roofline"]
                    print(
                        f"ok in {rec['compile_s']:.0f}s  compute {r['compute_s']:.4f}s"
                        f"  memory {r['memory_s']:.4f}s  collective {r['collective_s']:.4f}s"
                        f"  dominant={r['dominant']}", flush=True,
                    )
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f_ in failures:
            print("  ", f_)
    else:
        print("\nall requested cells passed")


if __name__ == "__main__":
    main()
