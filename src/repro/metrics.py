"""Ranking metrics for XMR evaluation (precision@k / recall@k)."""

from __future__ import annotations

from typing import Sequence

import numpy as np


def precision_at_k(pred_labels: np.ndarray, true: Sequence[np.ndarray], k: int) -> float:
    """Mean P@k; pred_labels [n, >=k] (-1 entries = padding, never count)."""
    n = pred_labels.shape[0]
    hits = 0.0
    for i in range(n):
        t = set(int(x) for x in true[i])
        p = [int(x) for x in pred_labels[i, :k] if x >= 0]
        hits += sum(1 for x in p if x in t) / k
    return hits / max(n, 1)


def recall_at_k(pred_labels: np.ndarray, true: Sequence[np.ndarray], k: int) -> float:
    n = pred_labels.shape[0]
    tot = 0.0
    for i in range(n):
        t = set(int(x) for x in true[i])
        if not t:
            continue
        p = [int(x) for x in pred_labels[i, :k] if x >= 0]
        tot += sum(1 for x in p if x in t) / len(t)
    return tot / max(n, 1)
