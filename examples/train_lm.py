"""Train a (reduced) assigned-architecture LM for a few hundred steps on CPU,
exercising the full production loop: prefetching data pipeline, AdamW +
cosine schedule, async checkpointing, auto-resume, failure injection, and
error-feedback gradient compression.

    PYTHONPATH=src python examples/train_lm.py [--arch hymba-1.5b] [--steps 200]
"""

import argparse
import logging
import shutil
import tempfile

from repro.configs import get_config, reduced_config
from repro.launch.train import train_loop


def main() -> None:
    logging.basicConfig(level=logging.INFO, format="%(levelname)s %(message)s")
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="hymba-1.5b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = reduced_config(get_config(args.arch))
    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="repro_lm_")
    print(f"training reduced {cfg.name} ({cfg.family}) for {args.steps} steps; "
          f"checkpoints -> {ckpt_dir}")

    out = train_loop(
        cfg, steps=args.steps, batch=args.batch, seq=args.seq,
        ckpt_dir=ckpt_dir, save_every=50,
        inject_failure_at=args.steps // 2,   # prove the retry/restore path
        compress_grads=True,
    )
    losses = out["losses"]
    print(f"\nloss: {losses[0]:.4f} -> {losses[-1]:.4f} "
          f"({out['steps_run']} steps, failure injected+recovered at "
          f"{args.steps // 2})")
    print("watchdog:", out["watchdog"])
    assert losses[-1] < losses[0], "loss should fall on the synthetic corpus"
    if args.ckpt_dir is None:
        shutil.rmtree(ckpt_dir, ignore_errors=True)


if __name__ == "__main__":
    main()
