"""End-to-end serving driver (the paper's deployment scenario, §6).

Builds a product-search model at enterprise *geometry* (d = 4M features,
L = 32^4 ≈ 1.05M labels, branching 32 — the paper's tree shape scaled from
100M to what a CPU container holds), then drives the serving stack in both
production settings:

* **batch** — ``serve_batch`` (double-buffered chunk dispatch), Table-4
  panel per masked-matmul method;
* **online** — a Poisson request stream through the async
  :class:`~repro.serving.MicroBatcher`, reporting queue-wait vs compute
  split and throughput alongside the blocking per-query baseline;
* **network** — ``--gateway PORT`` serves the model over HTTP (stdlib
  :class:`~repro.serving.ServingGateway`); with ``--partitions P`` the
  engine runs against a cross-process worker fleet exchanging beams over
  the socket RPC. Demo queries are driven through real HTTP requests and a
  curl recipe is printed for poking the running server.

``--tier int8`` (or ``int8_pruned`` / ``fp8``) serves a compressed storage
tier (:mod:`repro.quant`): per-partition memory shrinks several-fold and
the printed manifest shows the compressed bytes + tier/dtype columns;
quality vs the exact tier is reported as recall instead of bitwise parity.

    PYTHONPATH=src python examples/serve_search.py [--queries 256] [--small]
    PYTHONPATH=src python examples/serve_search.py --small --gateway 8080 \\
        [--partitions 2] [--tier int8]
"""

import argparse
import json
import os
import sys
import time
import urllib.request

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))  # benchmarks/
from benchmarks.common import build_benchmark_tree
from repro.data.xmr_data import XMRShape, benchmark_queries
from repro.serving import (
    BatchPolicy,
    MicroBatcher,
    PartitionConfig,
    QuantConfig,
    Query,
    QueryResult,
    ServeConfig,
    ServingGateway,
    XMRServingEngine,
)
from repro.serving.config import QUANT_TIERS


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--queries", type=int, default=256)
    ap.add_argument("--beam", type=int, default=10)
    ap.add_argument("--max-batch", type=int, default=16,
                    help="micro-batcher coalescing size")
    ap.add_argument("--max-wait-ms", type=float, default=2.0)
    ap.add_argument("--small", action="store_true",
                    help="32k labels / d=337k (fast demo)")
    ap.add_argument("--partitions", type=int, default=1,
                    help="label-space partitions (scatter-gather index; "
                         "per-device model bytes shrink ~1/P, results stay "
                         "bitwise-identical)")
    ap.add_argument("--gateway", type=int, default=None, metavar="PORT",
                    help="serve over HTTP on this port (0 = ephemeral); "
                         "with --partitions > 1 the engine runs against a "
                         "cross-process worker fleet")
    ap.add_argument("--tier", default="exact", choices=QUANT_TIERS,
                    help="weight storage tier (repro.quant): int8 / "
                         "int8_pruned cut per-partition memory several-"
                         "fold; fp8 is in-process only (no fleet wire)")
    args = ap.parse_args()
    if args.tier == "fp8" and args.gateway is not None and args.partitions > 1:
        ap.error("--tier fp8 cannot ship over the fleet RPC wire; "
                 "use --tier int8 with --partitions > 1")

    if args.small:
        shape = XMRShape("search-32k", 337_067, 32_768, 10_000, 100, 64)
    else:
        shape = XMRShape("search-1m", 4_000_000, 32**4, 10_000, 150, 64)
    rng = np.random.default_rng(0)

    print(f"building model: L={shape.L:,} labels, d={shape.d:,} ...")
    t0 = time.time()
    tree = build_benchmark_tree(shape, 32, rng)
    print(f"  built in {time.time() - t0:.0f}s, "
          f"{tree.memory_bytes() / 1e9:.2f} GB chunked weights, "
          f"depth {tree.depth}")

    queries = benchmark_queries(shape, args.queries, rng)

    if args.gateway is not None:
        serve_gateway(tree, queries, args)
        return
    if args.partitions > 1:
        serve_partitioned(tree, queries, shape, args)
        return

    print("\n== batch setting (Table 4 panel) ==")
    # A non-exact tier forces the quantized kernel, so the per-method
    # panel collapses to the single tier method.
    methods = (("mscm_dense", "mscm_searchsorted", "vanilla")
               if args.tier == "exact" else ("auto",))
    for method in methods:
        eng = XMRServingEngine(
            tree,
            ServeConfig(beam=args.beam, topk=10, method=method,
                        ell_width=256, max_batch=64,
                        quant=QuantConfig(tier=args.tier)),
        )
        eng.warmup(shape.d, batch_sizes=(64,))
        t0 = time.time()
        scores, labels = eng.serve_batch(queries)
        wall = time.time() - t0
        s = eng.latency_summary()["amortized"]
        print(f"{method:20s} amortized {s['avg_ms_per_query']:7.3f} ms/q "
              f"over {s['queries']} queries "
              f"({wall:.1f}s wall; per-query percentiles are an online-"
              f"setting metric)")

    print("\n== online setting (async micro-batching) ==")
    eng = XMRServingEngine(
        tree, ServeConfig(
            beam=args.beam, topk=10,
            method="mscm_dense" if args.tier == "exact" else "auto",
            ell_width=256, max_batch=64,
            quant=QuantConfig(tier=args.tier)))
    eng.warmup_buckets(shape.d, args.max_batch)

    n = min(args.queries, 128)
    t0 = time.perf_counter()
    eng.serve_online(queries, limit=n)
    base_qps = n / (time.perf_counter() - t0)
    print(f"{'per-query baseline':24s} {base_qps:8.1f} QPS (blocking loop)")

    mb = MicroBatcher(eng, BatchPolicy(args.max_batch, args.max_wait_ms))
    mb.start()
    futs = []
    for i in range(n):  # Poisson arrivals at 2x the baseline's capacity
        time.sleep(rng.exponential(1.0 / (2.0 * base_qps)))
        futs.append(mb.submit(*queries.row(i)))
    for f in futs:
        f.result(timeout=300)
    mb.stop()
    print(mb.metrics.table4_row(f"microbatch-{args.max_batch}"))

    print("\n(paper Table 4 at 100M labels on a single x86 thread: "
          "0.88 ms MSCM vs 7.28 ms vanilla — an 8x ratio; compare the ratios.)")


def serve_partitioned(tree, queries, shape, args) -> None:
    """Scatter-gather demo: the label space split P ways, end to end.

    Shows the manifest (per-partition label ranges + memory), then serves
    the same stream through the unpartitioned engine and the partitioned
    one and checks bitwise identity — the paper's enterprise scenario
    (a tree bigger than one device) without changing a single result bit.
    """
    p = args.partitions
    print(f"\n== partitioned serving (scatter-gather, P={p}) ==")
    ref = XMRServingEngine(
        tree, ServeConfig(beam=args.beam, topk=10, max_batch=64))
    ref_s, ref_l = ref.serve_batch(queries)

    engine = XMRServingEngine(
        tree, ServeConfig(beam=args.beam, topk=10, max_batch=64,
                          partition=PartitionConfig(partitions=p),
                          quant=QuantConfig(tier=args.tier)))
    m = engine.index.manifest
    print(f"split level {m.level}; router {m.router_memory_bytes / 1e6:.1f} MB"
          f" (replicated); per-device max "
          f"{m.max_partition_bytes() / 1e6:.1f} MB of "
          f"{m.total_memory_bytes / 1e6:.1f} MB total "
          f"({m.shrink_ratio():.2f}x shrink)")
    for info in m.partitions:
        print(f"  partition {info.pid}: labels [{info.label_start:>9,}, "
              f"{info.label_end:>9,})  {info.memory_bytes / 1e6:7.1f} MB  "
              f"tier {info.tier}/{info.dtype}  hash {info.content_hash}")

    mb = MicroBatcher(engine, BatchPolicy(args.max_batch, args.max_wait_ms))
    with mb:
        res = [f.result(timeout=600) for f in mb.submit_csr(queries)]
    s = np.stack([r[0] for r in res])
    l = np.stack([r[1] for r in res])
    if args.tier == "exact":
        identical = np.array_equal(s, ref_s) and np.array_equal(l, ref_l)
        print(f"\nbitwise-identical to unpartitioned: {identical}")
    else:
        from repro.quant import recall_at_k, score_mae

        print(f"\nquantized tier '{args.tier}' vs exact: "
              f"recall@10 {recall_at_k(ref_l, l):.4f}, "
              f"score MAE {score_mae(ref_s, s, 10):.5f}")
    summ = mb.metrics.summary()
    print(f"partition occupancy (share of top-k per partition): "
          f"{summ.get('partition_occupancy')}")
    print(mb.metrics.table4_row(f"partitioned-P{p}"))


def serve_gateway(tree, queries, args) -> None:
    """Serve the model over HTTP — in-process or against a worker fleet.

    With ``--partitions P`` the engine's per-level merge runs against P
    worker *subprocesses* (``repro.serving.fleet``) exchanging beams over a
    socket RPC; the gateway answers with results bitwise-identical to the
    in-process engine either way. Demo traffic goes through real HTTP
    requests so the printed numbers include the network edge.
    """
    p = args.partitions
    quant = QuantConfig(tier=args.tier)
    cfg = ServeConfig(beam=args.beam, topk=10, max_batch=64, quant=quant)
    if p > 1:
        cfg = ServeConfig(
            beam=args.beam, topk=10, max_batch=64, quant=quant,
            partition=PartitionConfig(partitions=p,
                                      partition_sync="pipelined"),
        )
    engine = XMRServingEngine(tree, cfg)

    fleet = None
    if p > 1:
        from repro.serving.fleet import PartitionFleet

        print(f"\nlaunching {p} partition workers ...")
        fleet = PartitionFleet.launch(p).attach(engine)
        print(f"  workers up: {fleet.ping()}")

    try:
        mb = MicroBatcher(engine,
                          BatchPolicy(args.max_batch, args.max_wait_ms))
        with mb, ServingGateway(mb, port=args.gateway, fleet=fleet) as gw:
            print(f"\n== HTTP gateway on {gw.url} ==")
            print(f"  POST {gw.url}/v1/query   "
                  '{"v": 1, "idx": [...], "val": [...]}')
            print(f"  GET  {gw.url}/healthz    GET  {gw.url}/metrics")
            print("  curl example:")
            idx, val = queries.row(0)
            wire = Query(idx=idx[:3], val=val[:3]).to_wire()
            print(f"    curl -s {gw.url}/v1/query -d '{json.dumps(wire)}'")

            n = min(args.queries, 64)
            t0 = time.perf_counter()
            for i in range(n):
                idx, val = queries.row(i)
                req = urllib.request.Request(
                    gw.url + "/v1/query",
                    data=json.dumps(Query(idx=idx, val=val,
                                          qid=i).to_wire()).encode(),
                    headers={"Content-Type": "application/json"},
                )
                with urllib.request.urlopen(req, timeout=300) as resp:
                    res = QueryResult.from_wire(json.load(resp))
                assert res.ok and res.qid == i
            wall = time.perf_counter() - t0
            print(f"\nserved {n} queries over HTTP in {wall:.1f}s "
                  f"({n / wall:.1f} QPS incl. network edge)")
            with urllib.request.urlopen(gw.url + "/metrics",
                                        timeout=30) as resp:
                summ = json.load(resp)
            print(f"avg_batch={summ.get('avg_batch', 0):.1f} "
                  f"p50={summ.get('p50_ms', 0):.2f}ms "
                  f"p99={summ.get('p99_ms', 0):.2f}ms")
            if fleet is not None:
                print(f"partition occupancy: "
                      f"{summ.get('partition_occupancy')}")
    finally:
        if fleet is not None:
            fleet.close()


if __name__ == "__main__":
    main()
