"""Quickstart: full XMR pipeline in ~a minute on CPU.

Builds a synthetic product-search-like dataset, clusters labels (PIFA +
balanced bisection), trains the per-level rankers, sparsifies, and serves
with every MSCM variant — verifying the paper's exactness claim and showing
the speedup live.

    PYTHONPATH=src python examples/quickstart.py
"""

import time

import jax.numpy as jnp
import numpy as np

from repro.data import synthetic_labeled_dataset
from repro.metrics import precision_at_k
from repro.trees.train import train_xmr_model


def main() -> None:
    rng = np.random.default_rng(0)
    print("1) generating synthetic dataset (512 labels, d=1024) ...")
    ds = synthetic_labeled_dataset(
        rng, n_labels=512, d=1024, n_train=2048, n_test=512, query_nnz=20
    )

    print("2) clustering + training per-level rankers (branching 8) ...")
    t0 = time.time()
    model = train_xmr_model(
        ds.x_train, ds.y_train, ds.n_labels, branching=8, rng=rng,
        nnz_per_col=64, steps=150,
    )
    print(f"   trained in {time.time() - t0:.1f}s; "
          f"model memory {model.tree.memory_bytes() / 1e6:.1f} MB")

    xi, xv = ds.x_test.to_ell(64)
    xi, xv = jnp.asarray(xi), jnp.asarray(xv)

    print("3) serving with each masked-matmul method:")
    ref_labels = None
    for method in ("vanilla", "mscm_dense", "mscm_searchsorted", "mscm_pallas"):
        scores, labels = model.predict(xi, xv, beam=16, topk=5, method=method)
        t0 = time.time()
        for _ in range(3):
            model.predict(xi, xv, beam=16, topk=5, method=method)
        dt = (time.time() - t0) / 3 / len(ds.y_test)
        p1 = precision_at_k(labels, ds.y_test, 1)
        if ref_labels is None:
            ref_labels = labels
        exact = "exact-match" if (labels == ref_labels).all() else "MISMATCH!"
        print(f"   {method:20s} P@1={p1:.3f}  {1e6 * dt:7.1f} us/query  [{exact}]")

    print("\nAll methods return identical rankings (paper's 'free of charge'"
          " property); MSCM variants are the fast ones.")


if __name__ == "__main__":
    main()
