"""MSCM vocab-tree head on an assigned LM: sub-linear decode over the vocab.

Takes the (reduced) seamless backbone's 256k-class output problem scaled to
a CPU demo: partitions a dense lm_head into a 2-level chunked tree and shows
(a) exactness at full beam, (b) agreement at practical beams, (c) latency.

    PYTHONPATH=src python examples/lm_tree_head.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.xmr_head import VocabTreeHead, greedy_token


def structured_head(key, d, vocab, branching):
    """Head weights with real-embedding-like cluster geometry: tokens in a
    chunk share a centroid (random heads have meaningless centroids and
    defeat any routing — real LM heads are strongly clustered)."""
    import jax, jax.numpy as jnp
    import numpy as np
    c = (vocab + branching - 1) // branching
    k1, k2 = jax.random.split(key)
    centers = jax.random.normal(k1, (c, d)) / np.sqrt(d)
    noise = jax.random.normal(k2, (c, branching, d)) / np.sqrt(d)
    w = centers[:, None, :] + 0.4 * noise                 # [C, B, d]
    return w.reshape(c * branching, d)[:vocab].T          # [d, V]


def main() -> None:
    d, vocab, branching = 1024, 65_536, 128
    key = jax.random.PRNGKey(0)
    head_w = structured_head(key, d, vocab, branching)
    hidden = jax.random.normal(jax.random.PRNGKey(1), (16, d))

    tree = VocabTreeHead.from_lm_head(head_w, branching)
    print(f"vocab {vocab:,} -> {tree.n_clusters} chunks of {branching}")

    dense = jax.jit(lambda h: jnp.argmax(h @ head_w, axis=1))
    full = np.asarray(dense(hidden))

    exact = np.asarray(greedy_token(tree, hidden, beam=tree.n_clusters))
    print(f"full-beam exactness: {(exact == full).mean():.3f} (must be 1.0)")

    t0 = time.time()
    for _ in range(10):
        jax.block_until_ready(dense(hidden))
    t_dense = (time.time() - t0) / 10

    for beam in (4, 16, 64):
        fn = jax.jit(lambda h, b=beam: greedy_token(tree, h, beam=b))
        jax.block_until_ready(fn(hidden))
        t0 = time.time()
        for _ in range(10):
            jax.block_until_ready(fn(hidden))
        t = (time.time() - t0) / 10
        agree = (np.asarray(fn(hidden)) == full).mean()
        print(f"beam {beam:3d}: {1e6 * t:8.1f} us  (dense {1e6 * t_dense:.1f} us, "
              f"{t_dense / t:4.1f}x)  argmax agreement {agree:.3f}")


if __name__ == "__main__":
    main()
